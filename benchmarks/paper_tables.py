"""One benchmark per paper table/figure (run via ``python -m benchmarks.run``).

Paper artifact -> bench:
  Fig. 5  clock overhead per opt level          -> bench_clock_overhead
  Table II ALU instruction latencies O3 vs O0   -> bench_alu_latency
  Table III version/level optimization deltas   -> bench_optlevels
  Fig. 6  global/L1/L2 + texture analog         -> bench_memory_hierarchy
  Table IV shared/constant memory analog        -> bench_onchip_memory
  Fig. 3  in-pipeline vs dispatch sampling      -> bench_inkernel_vs_dispatch
  Table IV + Fig. 6 in-kernel memory ladder     -> bench_inkernel_memory
  (Section I purpose) serving predicted-vs-meas -> bench_serving_cost
  (framework) attention/kernel-path comparison  -> bench_attention_impls
  (framework) sharded vs serial fan-out scaling -> bench_fanout_scaling
  (deliverable g) roofline table from dry-runs  -> bench_roofline
"""
from __future__ import annotations

import glob
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Plan, Session
from repro.core import chains, membench, optlevels, perfmodel
from repro.core.optlevels import OPT_LEVELS
from repro.core.timing import Timer
from repro.utils import dump_json, load_json, markdown_table

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _emit(rows: list[tuple[str, float, str]]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.4f},{derived}")


# ------------------------------------------------------------------- Fig. 5
def bench_clock_overhead(timer: Timer) -> list[tuple[str, float, str]]:
    # force=True: benches must re-measure, not report cached numbers.
    result = Session(timer=timer).run(Plan.clock_overhead(OPT_LEVELS), force=True)
    ov = {r.opt_level: r.latency_ns for r in result.records()}
    dump_json(ov, f"{RESULTS}/clock_overhead.json")
    return [(f"clock_overhead.{lv}", ns / 1e3,
             f"timing-region overhead at {lv} (paper Fig.5)")
            for lv, ns in sorted(ov.items())]


# ----------------------------------------------------------------- Table II
def bench_alu_latency(timer: Timer, quick: bool = False) -> list[tuple[str, float, str]]:
    keep = {"add", "mul", "div.s.runtime", "div.s.regular", "fma.float32",
            "div.runtime.float32", "sqrt", "sin", "popc", "add.bfloat16"
            } if quick else None
    session = Session(db=f"{RESULTS}/latency_db.json", timer=timer)
    session.run(Plan.instructions(ops=keep, opt_levels=("O0", "O3")), force=True)
    db = session.db
    with open(f"{RESULTS}/table2_alu_latency.md", "w") as f:
        f.write(db.table_markdown())
    rows = []
    for cat in chains.CATEGORIES:
        recs = [r for r in db.query(opt_level="O3") if r.category == cat]
        if recs:
            med = float(np.median([r.latency_ns for r in recs]))
            rows.append((f"alu.{cat}.O3_median", med / 1e3,
                         f"{len(recs)} ops measured (paper Table II)"))
    return rows


# ---------------------------------------------------------------- Table III
def bench_optlevels(timer: Timer) -> list[tuple[str, float, str]]:
    """O1-vs-O3 deltas + the jax-version key for cross-version diffs."""
    keep = {"div.s.runtime", "div.s.irregular", "div.runtime.float32",
            "mul64hi", "popc", "sqrt"}
    session = Session(db=f"{RESULTS}/latency_db.json", timer=timer)
    session.run(Plan.instructions(ops=keep, opt_levels=("O1", "O3")), force=True)
    db = session.db
    rows = []
    for name in sorted(keep):
        o1 = db.lookup_ns(name, "O1")
        o3 = db.lookup_ns(name, "O3")
        if o1 and o3:
            delta = 100 * (o3 - o1) / max(o1, 1e-9)
            rows.append((f"optlevel.{name}", o3 / 1e3,
                         f"O1={o1:.1f}ns O3={o3:.1f}ns delta={delta:+.0f}%"
                         f" [{optlevels.o1_option_string()}]"))
    with open(f"{RESULTS}/table3_optlevels.md", "w") as f:
        f.write(db.table_markdown(opt_levels=("O3", "O1", "O0")))
    return rows


# ------------------------------------------------------------------- Fig. 6
def bench_memory_hierarchy(timer: Timer, quick: bool = False
                           ) -> list[tuple[str, float, str]]:
    sizes = [1 << k for k in (range(13, 24, 2) if quick else range(12, 26))]
    result = Session(timer=timer).run(Plan.memory(sizes), force=True)
    pts = [membench.mempoint_from_record(r) for r in result.records()]
    levels = membench.detect_levels(pts)
    bw = membench.bandwidth_probe(timer=timer)
    dump_json({"points": [vars(p) for p in pts], "levels": levels,
               "stream_bw_GBs": bw}, f"{RESULTS}/fig6_memory.json")
    rows = [(f"mem.ws_{p.working_set_bytes}", p.latency_ns / 1e3,
             f"hit={p.latency_ns:.2f}ns cold={p.cold_latency_ns:.2f}ns")
            for p in pts]
    for lv in levels:
        rows.append((f"mem.level{lv['level']}", lv["hit_latency_ns"] / 1e3,
                     f"capacity>={lv['capacity_bytes_lower_bound']}B "
                     f"(paper Fig.6 hierarchy cliff)"))
    rows.append(("mem.stream_bandwidth", 0.0, f"{bw:.2f} GB/s"))
    return rows


# ------------------------------------------------------- multi-device fan-out
def bench_fanout_scaling(timer: Timer, quick: bool = False
                         ) -> list[tuple[str, float, str]]:
    """Sharded vs serial wall-clock for one plan (docs/fanout.md).

    On a single-device host the two are identical (1 shard); on an N-device
    host (or under --xla_force_host_platform_device_count) the sharded run
    should approach serial/N while producing the same record set.
    """
    ops = ("add", "mul", "sqrt", "popc") if quick else tuple(
        o.name for o in chains.default_registry()[:12])
    plan = Plan.instructions(ops=ops, opt_levels=("O3",))
    n_dev = len(jax.local_devices())

    t0 = time.perf_counter()
    serial = Session(timer=timer).run(plan, force=True)
    t_serial = time.perf_counter() - t0

    fan_session = Session(timer=Timer(warmup=timer.warmup, reps=timer.reps))
    t0 = time.perf_counter()
    fanned = fan_session.fan_out(plan, force=True)
    t_fan = time.perf_counter() - t0

    same = ({r.key() for r in serial.db.records()}
            == {r.key() for r in fanned.db.records()})
    dump_json({"devices": n_dev, "probes": len(plan), "serial_s": t_serial,
               "fanout_s": t_fan, "record_sets_equal": same},
              f"{RESULTS}/fanout_scaling.json")
    return [("fanout.serial", t_serial * 1e6, f"{len(plan)} probes, 1 device"),
            ("fanout.sharded", t_fan * 1e6,
             f"{len(plan)} probes over {n_dev} device shard(s), "
             f"speedup={t_serial / max(t_fan, 1e-9):.2f}x, "
             f"records_equal={same}")]


# ---------------------------------------------------------------- Table IV
def bench_onchip_memory(timer: Timer) -> list[tuple[str, float, str]]:
    """Shared/constant-memory analog: Pallas in-kernel chase (VMEM-resident)
    vs host-level chase, in interpret mode for correctness and with slope
    timing for the numbers (on TPU this is the real VMEM latency probe)."""
    from repro.kernels.ops import chase
    n = 512
    ring = membench._ring_permutation(n)
    ring_j = jnp.asarray(ring)
    start = jnp.asarray([0], jnp.int32)

    def fn_by_len(steps):
        return jax.jit(lambda r, s: chase(r, s, steps=steps, interpret=True))

    est = timer.slope(fn_by_len, 64, 192, ring_j, start, reps=5)
    host = membench.measure_latency(n * 64, timer=timer, steps=(512, 1536))
    dump_json({"vmem_analog_ns": est.median_ns, "host_ns": host.latency_ns},
              f"{RESULTS}/table4_onchip.json")
    return [("onchip.pallas_chase", max(est.median_ns, 0) / 1e3,
             "in-kernel dependent load (paper Table IV shared-mem analog; "
             "interpret mode on CPU)"),
            ("onchip.host_chase", host.latency_ns / 1e3,
             "host-level chase, same working set")]


# --------------------------------- Table IV + Fig. 6: in-kernel memory rows
def bench_inkernel_memory(timer: Timer, quick: bool = False
                          ) -> list[tuple[str, float, str]]:
    """In-kernel chase ladder + host twins (docs/memory.md): per-load latency
    vs working-set size with the residency (VMEM-pinned vs HBM-streaming)
    recorded per rung. On TPU the in-kernel column is the paper's Table IV /
    Fig. 6 number; in interpret mode it validates the machinery."""
    from repro.kernels.chase import VMEM_BUDGET_BYTES

    sizes = ([VMEM_BUDGET_BYTES >> 6, VMEM_BUDGET_BYTES, VMEM_BUDGET_BYTES << 1]
             if quick else None)
    session = Session(db=f"{RESULTS}/latency_db.json", timer=timer)
    result = session.run(Plan.memory_inkernel(sizes), force=True)
    db = session.db
    # the shared bench DB also holds op-chain pairings; the ladder artifact
    # renders only the memory family
    from repro.core.latency_db import LatencyDB

    mem_db = LatencyDB()
    mem_db.extend(r for r in db.records() if r.category == "memory")
    with open(f"{RESULTS}/inkernel_memory.md", "w") as f:
        f.write(mem_db.compare_markdown())
    points = []
    for r in result.records():
        if not r.op.startswith("inkernel.mem."):
            continue
        pt = membench.chasepoint_from_record(r)
        # env-filtered like compare_markdown: the shared bench DB accumulates
        # runs across devices/jax versions, and a cross-env pairing is
        # meaningless
        host = db.lookup_ns(f"mem.chase.ws{pt.working_set_bytes}",
                            **session.env)
        points.append({"working_set_bytes": pt.working_set_bytes,
                       "inkernel_ns": pt.latency_ns,
                       "host_ns": host,
                       "memory_space": pt.memory_space,
                       "line_bytes": pt.line_bytes})
    points.sort(key=lambda p: p["working_set_bytes"])
    dump_json({"vmem_budget_bytes": VMEM_BUDGET_BYTES, "points": points},
              f"{RESULTS}/inkernel_memory.json")
    rows = []
    for p in points:
        host = (f"{p['host_ns']:.2f}ns" if p["host_ns"] is not None else "—")
        rows.append((f"inkernel.mem.ws_{p['working_set_bytes']}",
                     p["inkernel_ns"] / 1e3,
                     f"space={p['memory_space']} host={host} "
                     "(paper Table IV/Fig. 6 in-kernel)"))
    crossed = sorted({p["memory_space"] for p in points})
    rows.append(("inkernel.mem.boundary", 0.0,
                 f"ladder spans residencies {crossed} around the "
                 f"{VMEM_BUDGET_BYTES >> 20}MiB VMEM budget"))
    return rows


# ------------------------------------------ Fig. 3: in-pipeline vs dispatch
def bench_inkernel_vs_dispatch(timer: Timer, quick: bool = False
                               ) -> list[tuple[str, float, str]]:
    """Paired dispatch-vs-in-kernel table (repro.inkernel): every eligible op
    measured both at dispatch granularity and as a Pallas fori_loop chain,
    side by side. On TPU the in-kernel column is the paper's in-pipeline
    number; in interpret mode (this container) it validates the machinery."""
    cats = ("int_arith", "fp32") if quick else None
    keep = {"add", "mul", "mad", "div.s.runtime", "fma.float32",
            "div.runtime.float32", "add.float32"} if quick else None
    session = Session(db=f"{RESULTS}/latency_db.json", timer=timer)
    session.run(Plan.inkernel(ops=keep, categories=cats), force=True)
    db = session.db
    md = db.compare_markdown()
    with open(f"{RESULTS}/inkernel_vs_dispatch.md", "w") as f:
        f.write(md)
    rows = []
    for cat in chains.CATEGORIES:
        recs = [r for r in db.query(opt_level="O3")
                if r.category == cat and r.op.startswith("inkernel.")]
        if recs:
            med = float(np.median([r.latency_ns for r in recs]))
            rows.append((f"inkernel.{cat}.median", med / 1e3,
                         f"{len(recs)} ops in-kernel (paper Fig. 3 method)"))
    return rows


# ------------------------------------------- serving predicted vs measured
def bench_serving_cost(timer: Timer, quick: bool = False
                       ) -> list[tuple[str, float, str]]:
    """Serving-path characterization (docs/serving.md): the Engine's prefill
    and decode-step HLO priced from the measured LatencyDB vs its wall
    clock, per (batch, prompt_len) cell. The paper's stated purpose made a
    bench: measured tables feeding a performance model of a real program."""
    from repro.api.plan import SERVING_CELLS

    cells = SERVING_CELLS[:1] if quick else SERVING_CELLS
    session = Session(db=f"{RESULTS}/latency_db.json", timer=timer)
    result = session.run(Plan.serving(cells=cells), force=True)
    db = session.db
    with open(f"{RESULTS}/serving_cost.md", "w") as f:
        f.write(db.compare_markdown(prefix="serving."))
    points = sorted(
        (perfmodel.servingpoint_from_record(r) for r in result.records()
         if r.op.startswith("serving.")),
        key=lambda p: (p.phase, p.batch, p.prompt_len))
    dump_json({"cells": [vars(p) for p in points]},
              f"{RESULTS}/serving_cost.json")
    rows = []
    for p in points:
        rows.append((f"serving.{p.phase}.b{p.batch}p{p.prompt_len}",
                     p.measured_ns / 1e3,
                     f"predicted={p.predicted_ns:.0f}ns ratio={p.ratio:.3f} "
                     f"coverage={p.coverage:.2f} (perfmodel x LatencyDB)"))
    return rows


# ------------------------------------------------- framework: attention path
def bench_attention_impls(timer: Timer) -> list[tuple[str, float, str]]:
    from repro.models import common
    b, s, h, kh, d = 2, 1024, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    rows = []
    for impl in ("plain", "blockwise"):
        fn = jax.jit(lambda q, k, v, impl=impl: common.attention(
            q, k, v, causal=True, impl=impl, block_k=256))
        m = timer.time_callable(fn, q, k, v, reps=10)
        rows.append((f"attention.{impl}", m.median_ns / 1e3,
                     f"B{b} S{s} H{h} D{d} f32 (host CPU)"))
    return rows


# ------------------------------------------------------- deliverable g table
def bench_roofline(_: Timer) -> list[tuple[str, float, str]]:
    files = sorted(glob.glob(f"{RESULTS}/dryrun/*__16x16.json"))
    rows_out, md_rows = [], []
    for f in files:
        rec = load_json(f)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        t_dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        md_rows.append([r[k] for k in ("arch", "shape")] +
                       [f"{r['t_compute']*1e3:.2f}", f"{r['t_memory']*1e3:.2f}",
                        f"{r['t_collective']*1e3:.2f}", r["dominant"],
                        f"{r['useful_ratio']:.1%}", f"{r['roofline_fraction']:.2%}"])
        rows_out.append((f"roofline.{r['arch']}.{r['shape']}", t_dom * 1e6,
                         f"{r['dominant']}-bound roofline={r['roofline_fraction']:.2%}"))
    md = markdown_table(["arch", "shape", "T_comp(ms)", "T_mem(ms)",
                         "T_coll(ms)", "bound", "useful", "roofline"], md_rows)
    with open(f"{RESULTS}/roofline_table.md", "w") as f:
        f.write(md)
    return rows_out
