import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

Three mandated cells (worst roofline / most collective-bound / most
representative); each variant is one knob change against the paper-faithful
baseline. Results: benchmarks/results/perf/<cell>__<tag>.json and a summary
table printed at the end. EXPERIMENTS.md §Perf narrates the iterations.
"""
import argparse
import sys

from repro.launch.dryrun import run_cell
from repro.utils import dump_json, logger

# (arch, shape) -> [(tag, rt_overrides, kwargs)]
PLANS = {
    ("llama3-405b", "train_4k"): [
        ("baseline", {}, {}),
        ("p_bf16", {"attn_p_dtype": "bfloat16"}, {}),
        ("p_bf16_mb4", {"attn_p_dtype": "bfloat16"}, {"microbatch": 4}),
        ("p_bf16_blk2k", {"attn_p_dtype": "bfloat16", "block_k": 2048}, {}),
        ("p_bf16_xent1k", {"attn_p_dtype": "bfloat16", "xent_chunk": 1024}, {}),
        ("zero3_gather", {"fsdp_gather_weights": True}, {}),
        ("zero3_blk2k", {"fsdp_gather_weights": True, "block_k": 2048}, {}),
        ("zero3_blk4k", {"fsdp_gather_weights": True, "block_k": 4096}, {}),
    ],
    ("llama4-maverick-400b-a17b", "train_4k"): [
        ("baseline", {}, {}),
        ("combine_reshard", {"moe_combine_reshard": True}, {}),
        ("combine_reshard_pbf16", {"moe_combine_reshard": True,
                                   "attn_p_dtype": "bfloat16"}, {}),
        ("cr_pbf16_mb2", {"moe_combine_reshard": True,
                          "attn_p_dtype": "bfloat16"}, {"microbatch": 2}),
        ("cr_zero3", {"moe_combine_reshard": True,
                      "fsdp_gather_weights": True}, {}),
        ("cr_zero3_blk2k", {"moe_combine_reshard": True,
                            "fsdp_gather_weights": True, "block_k": 2048}, {}),
    ],
    ("jamba-v0.1-52b", "long_500k"): [
        ("baseline", {}, {}),
        ("cache_headdim", {"cache_shard": "head_dim"}, {}),
        ("cache_headdim_cr", {"cache_shard": "head_dim",
                              "moe_combine_reshard": True}, {}),
        ("infer_sharding", {"infer_sharding": True}, {}),
        ("infer_moe_gather", {"infer_sharding": True,
                              "moe_gather_decode": True}, {}),
        ("infer_moe_gather_hd", {"infer_sharding": True,
                                 "moe_gather_decode": True,
                                 "cache_shard": "head_dim"}, {}),
        ("kvseq_consistent", {}, {}),
        ("cache_hd_fixed", {"cache_shard": "head_dim"}, {}),
        ("cache_hd_infer", {"cache_shard": "head_dim",
                            "infer_sharding": True}, {}),
    ],
}

OUT = "benchmarks/results/perf"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, help="arch/shape")
    ap.add_argument("--only", default=None, help="comma-separated tags")
    args = ap.parse_args()

    rows = []
    for (arch, shape), plan in PLANS.items():
        if args.cell and args.cell != f"{arch}/{shape}":
            continue
        for tag, overrides, kw in plan:
            if args.only and tag not in args.only.split(","):
                continue
            path = f"{OUT}/{arch}__{shape}__{tag}.json"
            if os.path.exists(path):
                logger.info("cached %s", path)
                continue
            logger.info("=== %s/%s [%s] %s", arch, shape, tag, overrides)
            try:
                rec = run_cell(arch, shape, multi_pod=False, save=False,
                               rt_overrides=overrides or None,
                               want_breakdown=True, **kw)
            except Exception as e:  # noqa: BLE001
                logger.exception("variant failed")
                rec = {"status": "fail", "error": str(e)[:2000]}
            rec["tag"] = tag
            rec["overrides"] = overrides
            dump_json(rec, path)
            if rec.get("status") == "ok":
                r = rec["roofline"]
                rows.append((f"{arch}/{shape}", tag, r["t_compute"],
                             r["t_memory"], r["t_collective"], r["dominant"],
                             r["roofline_fraction"]))
    for row in rows:
        print(f"{row[0]:45s} {row[1]:22s} comp={row[2]*1e3:9.2f}ms "
              f"mem={row[3]*1e3:9.2f}ms coll={row[4]*1e3:9.2f}ms "
              f"{row[5]:10s} roofline={row[6]:.2%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
